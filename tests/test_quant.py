"""UAQ semantics: error halves per extra bit; measured-accuracy oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant as Q


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_roundtrip_error_halves_per_bit(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64, 64))
    errs = [Q.quant_error(x, b) for b in (3, 4, 5, 6, 8)]
    for a, b in zip(errs, errs[1:]):
        assert b < a * 0.75  # geometric decay


def test_quantize_within_levels():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 32)) * 5
    for bits in (3, 4, 5, 8):
        q, s, z = Q.uaq_quantize(x, bits)
        assert int(q.max()) <= (1 << bits) - 1
        assert int(q.min()) >= 0


def test_per_axis_params():
    x = jnp.stack([jnp.linspace(0, 10, 8), jnp.linspace(0, 0.1, 8)])
    s, z = Q.uaq_params(x, 8, axis=0)
    assert s.shape == (2, 1)
    assert float(s[0, 0]) != float(s[1, 0])


def test_packed_bytes():
    assert Q.packed_bytes(1000, 4) == 508
    assert Q.packed_bytes(1000, 3) == 383
    assert Q.packed_bytes(1000, 8) == 1008


def test_measured_oracle_monotone():
    """Accuracy loss measured through a real head decreases with bits."""
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (16, 5))
    feats = jax.random.normal(jax.random.fold_in(key, 1), (200, 16)) * 3
    labels = jnp.argmax(feats @ w, -1)
    tail = lambda x: x @ w
    base = float(jnp.mean(jnp.argmax(tail(feats), -1) == labels))
    oracle = Q.measured_acc_oracle(tail, feats, labels, base)
    losses = [oracle(b) for b in (2, 3, 4, 6, 8)]
    assert losses[0] >= losses[-1]
    assert losses[-1] <= 0.01
