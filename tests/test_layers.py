"""Attention / rope / mask unit tests, including ring-buffer decode beyond
the sliding window and chunked-attention boundaries."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.config import LayerSpec, ModelConfig


def _cfg(**over):
    base = ModelConfig(
        name="t", arch_type="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=97, head_dim=16,
        sliding_window=8, attn_chunk=8)
    return dataclasses.replace(base, **over)


def test_rope_preserves_norm_and_relative():
    cfg = _cfg()
    pos = jnp.arange(12, dtype=jnp.int32)[None]
    cos, sin = L.rope_angles(pos, 16, 10_000.0)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 12, 2, 16))
    xr = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(jnp.linalg.norm(xr, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot_at(i, j):
        ci, si = L.rope_angles(jnp.array([[i]], jnp.int32), 16, 10_000.0)
        cj, sj = L.rope_angles(jnp.array([[j]], jnp.int32), 16, 10_000.0)
        return float(jnp.sum(L.apply_rope(q, ci, si) * L.apply_rope(k, cj, sj)))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4


def test_mrope_text_equals_1d_when_sections_share_positions():
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    cos1, sin1 = L.rope_angles(pos, 16, 10_000.0)
    cos3, sin3 = L.rope_angles(pos, 16, 10_000.0, mrope_sections=(2, 3, 3))
    np.testing.assert_allclose(cos1, cos3, rtol=1e-6)
    np.testing.assert_allclose(sin1, sin3, rtol=1e-6)


@pytest.mark.parametrize("kind,expect", [
    ("global", lambda i, j, cfg: j <= i),
    ("local", lambda i, j, cfg: (j <= i) and (j > i - cfg.sliding_window)),
    ("chunked", lambda i, j, cfg: (j <= i) and (j // cfg.attn_chunk == i // cfg.attn_chunk)),
])
def test_scores_mask(kind, expect):
    cfg = _cfg()
    spec = LayerSpec(attn_kind=kind)
    S = 20
    pos = jnp.arange(S, dtype=jnp.int32)
    m = L._scores_mask(pos, pos, cfg, spec, causal=True)
    for i in range(S):
        for j in range(S):
            assert bool(m[i, j]) == expect(i, j, cfg), (kind, i, j)


@pytest.mark.parametrize("kind", ["global", "local", "chunked"])
def test_decode_matches_full_attention(kind):
    """Token-by-token decode (ring buffers for local/chunked) must match the
    full-sequence forward at every position, incl. beyond the window."""
    cfg = _cfg()
    spec = LayerSpec(attn_kind=kind)
    S, B = 21, 2  # > 2x window: exercises ring wraparound
    key = jax.random.PRNGKey(3)
    p = L.init_attention(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model)) * 0.5
    y_full, _ = L.attention_full(p, x, cfg, spec)
    cache = L.init_kv_cache(cfg, spec, B, max_seq=S)
    for t in range(S):
        y, cache = L.attention_decode(p, x[:, t:t + 1], cache, jnp.int32(t),
                                      cfg, spec)
        np.testing.assert_allclose(y[:, 0], y_full[:, t], rtol=2e-4,
                                   atol=2e-4, err_msg=f"{kind} pos {t}")


@pytest.mark.parametrize("kind", ["global", "local", "chunked"])
def test_prefill_cache_then_decode(kind):
    cfg = _cfg()
    spec = LayerSpec(attn_kind=kind)
    S, B, MAX = 19, 2, 32
    key = jax.random.PRNGKey(4)
    p = L.init_attention(cfg, key)
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    y_full, _ = L.attention_full(p, x, cfg, spec)
    _, (k, v) = L.attention_full(p, x[:, :S - 1], cfg, spec)
    cache = L.prefill_to_cache(cfg, spec, k, v, MAX)
    y, _ = L.attention_decode(p, x[:, S - 1:], cache, jnp.int32(S - 1), cfg, spec)
    np.testing.assert_allclose(y[:, 0], y_full[:, -1], rtol=2e-4, atol=2e-4)


def test_query_chunked_attention_matches_direct():
    """The memory-efficient q-chunked path must equal direct attention."""
    cfg = _cfg(d_model=32, num_heads=2, num_kv_heads=1, head_dim=16)
    spec = LayerSpec(attn_kind="global")
    key = jax.random.PRNGKey(5)
    p = L.init_attention(cfg, key)
    S = L.Q_CHUNK * 2
    x = jax.random.normal(key, (1, S, cfg.d_model)) * 0.2
    y_chunked, _ = L.attention_full(p, x, cfg, spec)
    old = L.Q_CHUNK
    try:
        L.Q_CHUNK = S  # force the direct path
        y_direct, _ = L.attention_full(p, x, cfg, spec)
    finally:
        L.Q_CHUNK = old
    np.testing.assert_allclose(y_chunked, y_direct, rtol=2e-4, atol=2e-4)


def test_gqa_equals_mha_when_kv_repeated():
    cfg_gqa = _cfg(num_heads=4, num_kv_heads=2)
    p = L.init_attention(cfg_gqa, jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 10, cfg_gqa.d_model))
    y_gqa, _ = L.attention_full(p, x, cfg_gqa, LayerSpec())
    # MHA with k/v weights repeated per group must be identical
    cfg_mha = _cfg(num_heads=4, num_kv_heads=4)
    hd = cfg_gqa.head_dim
    wk = p["wk"].reshape(cfg_gqa.d_model, 2, hd)
    pm = dict(p)
    pm["wk"] = jnp.repeat(wk, 2, axis=1).reshape(cfg_gqa.d_model, 4 * hd)
    wv = p["wv"].reshape(cfg_gqa.d_model, 2, hd)
    pm["wv"] = jnp.repeat(wv, 2, axis=1).reshape(cfg_gqa.d_model, 4 * hd)
    y_mha, _ = L.attention_full(pm, x, cfg_mha, LayerSpec())
    np.testing.assert_allclose(y_gqa, y_mha, rtol=2e-4, atol=2e-4)


def test_softcap_bounds_logits():
    cfg = _cfg(attn_logit_softcap=5.0)
    # with a huge scale, uncapped logits would saturate the softmax onto the
    # max element; capped logits stay within tanh range
    q = jax.random.normal(jax.random.PRNGKey(8), (1, 4, 4, 16)) * 100
    k = jax.random.normal(jax.random.PRNGKey(9), (1, 4, 2, 16)) * 100
    v = jax.random.normal(jax.random.PRNGKey(10), (1, 4, 2, 16))
    mask = jnp.ones((4, 4), bool)
    out = L._attend(q, k, v, mask, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_encoder_bidirectional_attention():
    """hubert-style encoder (causal=False): position t attends to t+1."""
    cfg = _cfg(num_heads=2, num_kv_heads=2)
    cfg = dataclasses.replace(cfg, causal=False)
    spec = LayerSpec(attn_kind="global")
    p = L.init_attention(cfg, jax.random.PRNGKey(11))
    x = jax.random.normal(jax.random.PRNGKey(12), (1, 8, cfg.d_model))
    y1, _ = L.attention_full(p, x, cfg, spec)
    # perturb the LAST token: with bidirectional attention the FIRST
    # position's output must change; with causal it must not
    x2 = x.at[:, -1].add(1.0)
    y2, _ = L.attention_full(p, x2, cfg, spec)
    assert float(jnp.max(jnp.abs(y2[:, 0] - y1[:, 0]))) > 1e-6
    cfg_c = dataclasses.replace(cfg, causal=True)
    y1c, _ = L.attention_full(p, x, cfg_c, spec)
    y2c, _ = L.attention_full(p, x2, cfg_c, spec)
    assert float(jnp.max(jnp.abs(y2c[:, 0] - y1c[:, 0]))) < 1e-6


def test_mrope_distinct_streams_differ_from_1d():
    """With genuinely different (t,h,w) positions, M-RoPE != 1-D RoPE."""
    pos3 = jnp.stack([jnp.arange(8), jnp.arange(8) * 2, jnp.zeros(8)],
                     axis=0).astype(jnp.int32)[:, None, :]  # (3,1,8)
    cos3, sin3 = L.rope_angles(pos3, 16, 10_000.0, mrope_sections=(2, 3, 3))
    cos1, sin1 = L.rope_angles(jnp.arange(8, dtype=jnp.int32)[None], 16,
                               10_000.0)
    assert not bool(jnp.allclose(cos3, cos1))
