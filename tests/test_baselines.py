"""Baseline schedulers (NS/DADS/SPINN/JPS) vs COACH on the paper's models."""

import pytest

from repro.core import baselines as BL
from repro.core.costs import A6000_SERVER, JETSON_NX, WIFI_5GHZ
from repro.core.partitioner import coach_offline
from repro.core.pipeline import plan_from_stage_times, run_pipeline
from repro.models.cnn import resnet101, vgg16


@pytest.fixture(scope="module")
def setting():
    return resnet101(), JETSON_NX, A6000_SERVER, WIFI_5GHZ(20)


def test_all_baselines_produce_valid_decisions(setting):
    g, e, c, l = setting
    for name, fn in BL.BASELINES.items():
        r = fn(g, e, c, l)
        assert g.valid_end_set(r.decision.end_set), name
        assert r.times.latency > 0


def test_ns_minimizes_single_task_latency(setting):
    g, e, c, l = setting
    ns = BL.neurosurgeon(g, e, c, l)
    for other in (BL.dads, BL.jps):
        assert ns.times.latency <= other(g, e, c, l).times.latency + 1e-12


def test_jps_balances_end_and_tx(setting):
    g, e, c, l = setting
    r = BL.jps(g, e, c, l)
    # by construction JPS's max(T_e, T_t) is minimal among chain cuts at 8 bits
    assert max(r.times.T_e, r.times.T_t) <= r.times.latency


def test_coach_beats_baselines_on_pipeline_throughput():
    """The paper's central claim: the full COACH system (offline + online)
    achieves >= saturation throughput than every baseline, across models
    and bandwidths (same cost model & task stream)."""
    from benchmarks.common import run_baseline, run_coach
    for g in (resnet101(), vgg16()):
        for mbps in (20, 50, 100):
            tp_coach = run_coach(g, "NX", mbps, "medium", n_tasks=400,
                                 arrival_factor=0.0).throughput
            for name in BL.BASELINES:
                tp = run_baseline(name, g, "NX", mbps, "medium",
                                  n_tasks=400, arrival_factor=0.0).throughput
                assert tp_coach >= tp * 0.95, (g.name, mbps, name,
                                               tp_coach, tp)


def test_spinn_has_nonempty_end(setting):
    g, e, c, l = setting
    r = BL.spinn(g, e, c, l)
    assert len(r.decision.end_set) >= 1
