"""Fused boundary pass: kernel vs exact-jnp reference, odd-channel wire
regression, the runtime's fused hop (one HBM read serving both the wire
packet and the semantic probe), and the sim/async engine differential
with fused probe results in the decision loop.

(Deliberately hypothesis-free: unlike ``test_kernels.py`` this file also
runs on hosts without the property-testing extra installed.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import online as ON
from repro.core.collab import BoundaryProbe, CollabRuntime, WirePacket
from repro.core.costs import (A6000_SERVER, JETSON_NX, WIFI_5GHZ)
from repro.core.schedule import StageTimes
from repro.configs import get_config
from repro.data.pipeline import CorrelatedTaskStream, make_calibration_set
from repro.kernels import ops, ref
from repro.kernels.boundary import fused_boundary
from repro.models import model as M
from repro.serving.async_engine import AsyncCoachEngine
from repro.serving.engine import CoachEngine


# ------------------------------------------------------- kernel vs ref
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("B,S,D,L", [(2, 64, 32, 5), (3, 100, 33, 4),
                                     (1, 1, 16, 2)])
def test_fused_boundary_kernel_bitexact_vs_jitted_ref(B, S, D, L, bits):
    """Interpret-mode kernel == jitted exact reference, bit for bit, on
    the wire fields for every shape and on everything for single-S-block
    shapes (the ref is compared *jitted* so both sides see XLA's
    reciprocal rewrite of the division by qmax)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D)) * 2.0
    c = jax.random.normal(jax.random.PRNGKey(1), (L, D))
    out_k = fused_boundary(x, c, bits, interpret=True)
    out_r = jax.jit(lambda a, b: ref.fused_boundary_ref(a, b, bits))(x, c)
    payload, scale, zp, feat, sep, best, sims = out_k
    pr, sr, zr, fr, sep_r, best_r, sims_r = out_r
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(scale), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(zp), np.asarray(zr))
    np.testing.assert_array_equal(np.asarray(best), np.asarray(best_r))
    np.testing.assert_array_equal(np.asarray(feat), np.asarray(fr))
    np.testing.assert_array_equal(np.asarray(sims), np.asarray(sims_r))
    np.testing.assert_array_equal(np.asarray(sep), np.asarray(sep_r))
    assert payload.shape == (B, S, (D + 1) // 2 if bits == 4 else D)


@pytest.mark.parametrize("bits", [4, 8])
def test_boundary_pass_dispatches_to_exact_ref_off_tpu(bits):
    """The runtime entry point off-TPU *is* the jitted reference (same
    bits), so the fused path and the test oracle cannot drift."""
    if jax.default_backend() == "tpu":
        pytest.skip("off-TPU dispatch path")
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, 48))
    c = jax.random.normal(jax.random.PRNGKey(3), (6, 48))
    got = ops.boundary_pass(x, c, bits)
    want = jax.jit(lambda a, b: ref.fused_boundary_ref(a, b, bits))(x, c)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ------------------------------------------------ odd-channel wire path
@pytest.mark.parametrize("n", [5, 33, 129])
@pytest.mark.parametrize("bits", [4, 8])
def test_wire_roundtrip_odd_channels(n, bits):
    """Regression (int4 odd channel dims): quantize -> dequantize through
    the shared entry points restores the true channel count with at most
    half a quantum of error; scale/zp are computed on the true N."""
    x = jax.random.normal(jax.random.PRNGKey(4), (8, n)) * 3.0
    p, s, z = ops.quantize_activation(x, bits, use_kernel=False)
    assert p.shape == (8, (n + 1) // 2 if bits == 4 else n)
    y = ops.dequantize_activation(p, s, z, bits, use_kernel=False,
                                  channels=n)
    assert y.shape == x.shape
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert (err <= np.asarray(s) * 0.5 * (1 + 1e-3)).all()


# --------------------------------------------------- ProbeResult lifting
def test_probe_result_from_fused_scatters_to_full_label_space():
    sims = np.array([0.9, 0.2, 0.6])
    pr = ON.ProbeResult.from_fused(sims, sep=1.7, best=0,
                                   valid=np.array([3, 5, 8]), n_labels=10)
    full = np.zeros(10)
    full[[3, 5, 8]] = sims
    np.testing.assert_array_equal(pr.sims, full)
    assert pr.best == 3 and pr.sep == 1.7


def test_probe_result_from_fused_cold_cache_never_exits():
    # < 2 trained centers: no genuine second-highest degree, sep forced 0
    pr = ON.ProbeResult.from_fused(np.array([0.9]), sep=5.0, best=0,
                                   valid=np.array([4]), n_labels=6)
    assert pr.sep == 0.0 and pr.best == 4
    pr = ON.ProbeResult.from_fused(np.zeros(0), sep=5.0, best=0,
                                   valid=np.zeros(0, int), n_labels=6)
    assert pr.sep == 0.0 and pr.best == 0 and not pr.sims.any()


def test_scheduler_step_consumes_probe_result():
    """A supplied ProbeResult replaces the cache recompute: an enormous
    separability forces the exit the cache's own sims would not take,
    and sep = 0 blocks exit regardless of the features."""
    stream = CorrelatedTaskStream(n_labels=8, dim=16, correlation="high",
                                  seed=0)
    feats, labels = make_calibration_set(stream, 200)
    eng = CoachEngine(None, StageTimes(
        T_e=2e-3, T_t=3e-3, T_c=2e-3, T_t_par=0, T_c_par=0, latency=7e-3,
        first_tx_offset=2e-3, cloud_start_offset=3e-3), JETSON_NX,
        WIFI_5GHZ(20), A6000_SERVER, n_labels=8, calib_feats=feats,
        calib_labels=labels, boundary_elems=10_000)
    sched = eng.sched
    f = feats[0]
    force = ON.ProbeResult(sims=np.full(8, 0.5), sep=1e9, best=3)
    dec = sched.step(f, probe=force)
    assert dec.early_exit and dec.result == 3
    block = ON.ProbeResult(sims=np.full(8, 0.5), sep=0.0, best=3)
    dec = sched.step(f, probe=block)
    assert not dec.early_exit


# ------------------------------------------------------- runtime fused hop
def _runtime():
    cfg = get_config("gemma2-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, CollabRuntime(cfg, params, cut_group=1)


def _inputs(cfg, key, batch=2):
    if cfg.embed_inputs:
        return jax.random.normal(key, (batch, 8, cfg.d_model))
    return jax.random.randint(key, (batch, 8), 0, cfg.vocab_size, jnp.int32)


def test_end_step_fused_matches_classic_hop():
    """The fused end hop emits the same wire packet as the classic
    quantize path plus a probe consistent with the boundary activation,
    and the cloud consumes the packet identically."""
    cfg, rt = _runtime()
    x = _inputs(cfg, jax.random.PRNGKey(1))
    centers = jax.random.normal(jax.random.PRNGKey(2), (5, cfg.d_model))
    pkt_c, h = rt.segment_step(0, x)
    pkt_f, probe = rt.end_step_fused(x, centers)
    assert isinstance(pkt_f, WirePacket) and isinstance(probe, BoundaryProbe)
    assert pkt_f.channels == cfg.d_model
    np.testing.assert_array_equal(np.asarray(pkt_f.payload),
                                  np.asarray(pkt_c.payload))
    np.testing.assert_array_equal(np.asarray(pkt_f.scale),
                                  np.asarray(pkt_c.scale))
    np.testing.assert_array_equal(np.asarray(pkt_f.zp),
                                  np.asarray(pkt_c.zp))
    # probe outputs == the unfused probe of the same boundary activation
    sep_r, best_r, sims_r = ref.semantic_probe_ref(
        h.astype(jnp.float32), centers)
    np.testing.assert_array_equal(np.asarray(probe.best),
                                  np.asarray(best_r))
    np.testing.assert_allclose(np.asarray(probe.sims), np.asarray(sims_r),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(probe.sep), np.asarray(sep_r),
                               rtol=1e-4, atol=1e-5)
    gap = np.asarray(jnp.sum(h.astype(jnp.float32), axis=1) / h.shape[1])
    np.testing.assert_allclose(np.asarray(probe.feat), gap, atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(rt.cloud_step(pkt_f)), np.asarray(rt.cloud_step(pkt_c)))


def test_segment_handle_fused_delivers_probe():
    cfg, rt = _runtime()
    x = _inputs(cfg, jax.random.PRNGKey(3))
    centers = jax.random.normal(jax.random.PRNGKey(4), (4, cfg.d_model))
    seen = {}
    h = rt.segment_handle(0, probe_centers=lambda: centers,
                          on_probe=lambda k, p: seen.setdefault(k, p))
    pkt = h(x)
    assert isinstance(pkt, WirePacket)
    assert 0 in seen and isinstance(seen[0], BoundaryProbe)
    pkt_f, probe = rt.end_step_fused(x, centers)
    np.testing.assert_array_equal(np.asarray(pkt.payload),
                                  np.asarray(pkt_f.payload))
    np.testing.assert_array_equal(np.asarray(seen[0].sims),
                                  np.asarray(probe.sims))


# --------------------------------------- engine differential, fused probes
def _fused_classify(stream):
    """Deterministic, engine-state-free fused-style classify: the probe
    outputs are a pure function of the task, so both engines must reach
    identical decisions from them."""
    mu = stream.mu / np.linalg.norm(stream.mu, axis=1, keepdims=True)

    def f(task):
        fn = task.features / max(np.linalg.norm(task.features), 1e-12)
        sims = (mu @ fn + 1.0) * 0.5
        order = np.argsort(-sims)
        t_h, t_sh = float(sims[order[0]]), float(sims[order[1]])
        sep = (t_h - t_sh) * t_h / max(t_sh, 1e-12)
        pr = ON.ProbeResult(sims=sims, sep=sep, best=int(order[0]))
        return task.features, int(order[0]), pr
    return f


def test_async_engine_decisions_identical_with_fused_probes():
    """Decision determinism holds with the fused probe in the loop: the
    3-tuple classify protocol yields identical sync/async EngineStats."""
    st = StageTimes(T_e=2e-3, T_t=3e-3, T_c=2e-3, T_t_par=0, T_c_par=0,
                    latency=7e-3, first_tx_offset=2e-3,
                    cloud_start_offset=3e-3)
    stream = CorrelatedTaskStream(n_labels=12, dim=32, correlation="high",
                                  seed=11)
    feats, labels = make_calibration_set(stream, 300)
    mk = lambda cls: cls(None, st, JETSON_NX, WIFI_5GHZ(20), A6000_SERVER,
                         n_labels=12, calib_feats=feats,
                         calib_labels=labels, boundary_elems=50_000)
    classify = _fused_classify(stream)
    tasks = list(stream.tasks(200))
    s = mk(CoachEngine).run_stream(list(tasks), arrival_period=3e-3,
                                   classify=classify)
    a = mk(AsyncCoachEngine).run_stream(list(tasks), arrival_period=3e-3,
                                        classify=classify)
    assert s.exit_ratio == a.exit_ratio
    assert s.mean_bits == a.mean_bits
    assert s.accuracy == a.accuracy
    assert abs(s.pipeline.makespan - a.pipeline.makespan) < 1e-6
