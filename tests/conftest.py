import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Property-based test modules need hypothesis (declared in
# requirements-dev.txt).  Skip collecting them gracefully when it is not
# installed so the rest of the suite still runs.
collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += [
        "test_kernels.py",
        "test_obs_props.py",
        "test_online.py",
        "test_partitioner.py",
        "test_pipeline.py",
        "test_pool_props.py",
        "test_quant.py",
        "test_ssm.py",
        "test_tenancy_props.py",
    ]
