"""Sharding rules: every spec axis must divide its dim on the production
meshes, for every architecture (params + caches)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, shape_supported
from repro.launch import steps as ST
from repro.launch.sharding import (activation_specs, cache_spec, param_spec,
                                   shard_cache, shard_params)


class FakeMesh:
    """Shape-only stand-in (no devices needed to validate the rules)."""

    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16})]


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(np.prod([mesh.shape[e] for e in entry]))
    return mesh.shape[entry]


def _check_spec(spec, shape, mesh, what):
    assert len(spec) <= len(shape), (what, spec, shape)
    for dim, entry in zip(shape, spec):
        size = _axis_size(mesh, entry)
        assert dim % size == 0, (what, spec, shape, dim, size)
    # no mesh axis used twice
    used = []
    for entry in spec:
        if entry is None:
            continue
        used += list(entry) if isinstance(entry, tuple) else [entry]
    assert len(used) == len(set(used)), (what, spec)


@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    aparams = ST.abstract_params(cfg, jnp.bfloat16)
    flat = jax.tree_util.tree_flatten_with_path(aparams)[0]
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        spec = param_spec(pstr, leaf.shape, mesh, cfg.num_groups)
        _check_spec(spec, leaf.shape, mesh, f"{arch}:{pstr}")


@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_cache_specs_divide(arch, mesh):
    cfg = get_config(arch)
    for sname in ("decode_32k", "long_500k"):
        shape = SHAPES[sname]
        ok, _ = shape_supported(cfg, shape)
        if not ok:
            continue
        acache = ST.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        for leaf in jax.tree.leaves(acache):
            spec = cache_spec(mesh, cfg, shape.global_batch, leaf.shape)
            _check_spec(spec, leaf.shape, mesh, f"{arch}:{sname}:{leaf.shape}")


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_activation_specs_well_formed(arch):
    cfg = get_config(arch)
    for mesh in MESHES:
        specs = activation_specs(cfg, mesh, 256)
        for name, spec in specs.items():
            if spec is None:
                continue
            used = []
            for entry in spec:
                if entry is None:
                    continue
                used += list(entry) if isinstance(entry, tuple) else [entry]
            assert len(used) == len(set(used)), (arch, name, spec)


def test_row_parallel_orientation():
    mesh = MESHES[0]
    # w_down: contraction dim (F) on model, output on data
    s = param_spec("groups/0/mlp/w_down", (13, 9216, 2304), mesh, 13)
    assert s[1] == "model"
    # w_gate: column-parallel
    s = param_spec("groups/0/mlp/w_gate", (13, 2304, 9216), mesh, 13)
    assert s[2] == "model"
    # embed: vocab on model (matches logits constraint)
    s = param_spec("embed", (256000, 2304), mesh, 13)
    assert s[0] == "model"
