"""Training integration: loss decreases on learnable synthetic data; the
optimizer/schedule behave; checkpoint-resume continues identically."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train
from repro.training import optim as O


def test_cosine_schedule_shape():
    cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_frac=0.1)
    lrs = [float(O.cosine_lr(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5, abs=1e-6)
    assert lrs[2] == pytest.approx(1.0, abs=1e-2)
    assert lrs[-1] == pytest.approx(0.1, abs=1e-2)
    assert lrs[3] > lrs[4]


def test_adamw_decreases_quadratic():
    cfg = O.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                        weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = O.adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = O.adamw_update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


@pytest.mark.slow
def test_train_loss_decreases():
    _, losses = train("gemma2-2b", smoke=True, steps=40, batch=8, seq=128,
                      lr=3e-3, log_every=100)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


def test_train_checkpoint_resume(tmp_path):
    d = str(tmp_path / "ck")
    train("mamba2-130m", smoke=True, steps=4, batch=2, seq=64,
          ckpt_dir=d, ckpt_every=2, log_every=100)
    from repro.checkpoint import latest_step
    assert latest_step(d) == 4
