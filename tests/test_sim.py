"""Unified N-stage simulator (`repro.core.sim`):

  * parity — the generalized path with one link must reproduce the seed
    3-resource event semantics (StageTimes / PipelineResult) to 1e-9, on
    partitions where each boundary producer feeds a single edge (the one
    regime where the seed's per-producer arrival bookkeeping was correct);
  * regression — a producer feeding several boundary edges gates each
    consumer on the edge it actually consumes (the seed overwrote the
    earlier arrival with the later one);
  * properties — 3-hop bubble accounting: per-resource busy <= makespan,
    latency monotone in added hop time, non-negative bubbles.

Property-style cases are driven by seeded numpy randomness (no hypothesis
dependency: this module must collect everywhere).
"""

import numpy as np
import pytest

from repro.core import sim
from repro.core.costs import (DeviceProfile, LinkProfile, ModelGraph,
                              chain_graph)
from repro.core.pipeline import (PipelineResult, TaskPlan,
                                 bandwidth_step_trace, run_pipeline)
from repro.core.schedule import (PartitionDecision, evaluate_multihop,
                                 evaluate_partition)

END = DeviceProfile("end", 1e9)
CLOUD = DeviceProfile("cloud", 8e9)
EDGE = DeviceProfile("edge", 3e9)
LINK = LinkProfile("link", 100e6)
BACKHAUL = LinkProfile("backhaul", 900e6)


# ------------------------------------------------- seed reference semantics
def seed_evaluate_partition(graph, decision, end_dev, cloud_dev, link,
                            input_bits_per_elem=8):
    """The seed's 3-resource event loop, verbatim (incl. per-producer
    ``recv`` keying) — the parity oracle for the generalized core."""
    end_set = decision.end_set
    t = 0.0
    end_done, end_intervals = {}, []
    for n in graph.nodes:
        if n.id in end_set:
            dt = end_dev.layer_time(n.flops, n.util)
            end_intervals.append((t, t + dt))
            t += dt
            end_done[n.id] = t
    T_e = t

    ready = []
    for (u, v) in graph.boundary_edges(end_set):
        when = 0.0 if u < 0 else end_done[u]
        bits = graph.input_elems * input_bits_per_elem if u < 0 \
            else graph.node(u).out_elems * decision.bits.get((u, v), 32)
        ready.append((when, (u, v), bits))
    ready.sort(key=lambda r: (r[0], r[1]))

    link_free, T_t, first_tx_start = 0.0, 0.0, None
    recv, link_intervals = {}, []
    for (when, (u, v), bits) in ready:
        start = max(when, link_free)
        dur = link.transfer_time(bits, start)
        link_intervals.append((start, start + dur))
        if first_tx_start is None:
            first_tx_start = start
        link_free = start + dur
        T_t += dur
        recv[u] = link_free

    t, T_c = 0.0, 0.0
    cloud_done, cloud_intervals = {}, []
    for n in graph.nodes:
        if n.id in end_set:
            continue
        ready_at = 0.0
        for d in n.deps:
            ready_at = max(ready_at,
                           recv[d] if d in end_set else cloud_done[d])
        if not n.deps:
            ready_at = recv.get(-1, 0.0)
        dt = cloud_dev.layer_time(n.flops, n.util)
        start = max(t, ready_at)
        cloud_intervals.append((start, start + dt))
        t = start + dt
        cloud_done[n.id] = t
        T_c += dt

    finish = max([T_e] + list(cloud_done.values()) + [link_free])
    T_t_par = sim.overlap_total(link_intervals, end_intervals)
    T_c_par = sim.overlap_total(cloud_intervals, link_intervals)
    first_tx = first_tx_start if first_tx_start is not None else T_e
    cloud_first = min((s for s, _ in cloud_intervals), default=first_tx)
    return dict(T_e=T_e, T_t=T_t, T_c=T_c, T_t_par=T_t_par, T_c_par=T_c_par,
                latency=finish, first_tx_offset=first_tx,
                cloud_start_offset=max(0.0, cloud_first - first_tx))


def seed_run_pipeline(plans, arrivals=None, arrival_period=0.0, link=None):
    """The seed's hand-rolled end/link/cloud stream loop, verbatim."""
    n = len(plans)
    if arrivals is None:
        arrivals = [i * arrival_period for i in range(n)]
    end_free = link_free = cloud_free = 0.0
    end_busy = link_busy = cloud_busy = 0.0
    recs = []
    for i, (p, arr) in enumerate(zip(plans, arrivals)):
        e_start = max(arr, end_free)
        e_done = e_start + p.t_end
        end_free = e_done
        end_busy += p.t_end
        if p.early_exit:
            recs.append((i, arr, e_done, e_done - arr, True))
            continue
        tx_ready = e_done if p.tx_offset is None or p.tx_offset >= p.t_end \
            else e_start + p.tx_offset
        t_start = max(tx_ready, link_free)
        t_dur = p.t_tx
        if link is not None and link.trace is not None and p.t_tx > 0:
            bits = p.t_tx * link.bandwidth_bps
            t_dur = link.transfer_time(bits, t_start)
        t_done = t_start + t_dur
        link_free = t_done
        link_busy += t_dur
        c_ready = t_done if p.cloud_offset is None \
            else max(t_start + p.cloud_offset, tx_ready)
        c_start = max(c_ready, cloud_free)
        c_done = max(c_start + p.t_cloud, t_done)
        cloud_free = c_done
        cloud_busy += p.t_cloud
        recs.append((i, arr, c_done, c_done - arr, False))
    makespan = max(r[2] for r in recs) - min(r[1] for r in recs)
    return recs, makespan, end_busy, link_busy, cloud_busy


# ----------------------------------------------------------------- fixtures
def _chain(seed=0, n=10):
    rng = np.random.RandomState(seed)
    flops = rng.uniform(1e6, 5e7, n)
    elems = rng.randint(1_000, 200_000, n)
    return chain_graph(f"chain{seed}", flops, elems)


def _single_edge_cases(graph):
    """(end_set, bits) partitions of a chain: every boundary producer feeds
    exactly one edge, so seed and per-edge arrival semantics agree."""
    n = len(graph)
    cases = []
    for cut in (0, 1, n // 2, n - 1, n):
        end_set = frozenset(range(cut))
        bits = {(cut - 1, cut): 8} if 0 < cut < n else {}
        cases.append((end_set, bits))
    return cases


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_stage_times_parity_with_seed_semantics(seed):
    g = _chain(seed)
    for end_set, bits in _single_edge_cases(g):
        dec = PartitionDecision(end_set, bits)
        st = evaluate_partition(g, dec, END, CLOUD, LINK)
        ref = seed_evaluate_partition(g, dec, END, CLOUD, LINK)
        for f, want in ref.items():
            assert abs(getattr(st, f) - want) < 1e-9, (f, cut_info(end_set))


def cut_info(end_set):
    return f"|end|={len(end_set)}"


def test_stage_times_parity_under_bandwidth_trace():
    g = _chain(7)
    trace = bandwidth_step_trace([(0.0, 100.0), (0.005, 10.0), (0.02, 60.0)])
    link = LinkProfile("dyn", 100e6, trace=trace)
    for end_set, bits in _single_edge_cases(g):
        dec = PartitionDecision(end_set, bits)
        st = evaluate_partition(g, dec, END, CLOUD, link)
        ref = seed_evaluate_partition(g, dec, END, CLOUD, link)
        for f, want in ref.items():
            assert abs(getattr(st, f) - want) < 1e-9, f


def _random_plans(seed, n=40):
    rng = np.random.RandomState(seed)
    plans = []
    for _ in range(n):
        t_end = rng.uniform(1e-3, 5e-3)
        if rng.rand() < 0.2:
            plans.append(TaskPlan(t_end, 0.0, 0.0, True))
            continue
        t_tx = rng.uniform(0.5e-3, 4e-3)
        t_cloud = rng.uniform(1e-3, 5e-3)
        tx_off = rng.uniform(0, t_end) if rng.rand() < 0.5 else None
        cl_off = rng.uniform(0, t_tx) if rng.rand() < 0.5 else None
        plans.append(TaskPlan(t_end, t_tx, t_cloud,
                              tx_offset=tx_off, cloud_offset=cl_off))
    return plans


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("traced", [False, True])
def test_run_pipeline_parity_with_seed_semantics(seed, traced):
    plans = _random_plans(seed)
    link = None
    if traced:
        link = LinkProfile("dyn", 50e6, trace=bandwidth_step_trace(
            [(0.0, 50.0), (0.03, 8.0), (0.1, 80.0)]))
    pr = run_pipeline(plans, arrival_period=2.5e-3, link=link)
    recs, makespan, e_busy, l_busy, c_busy = seed_run_pipeline(
        plans, arrival_period=2.5e-3, link=link)
    assert abs(pr.makespan - makespan) < 1e-9
    assert abs(pr.end_busy - e_busy) < 1e-9
    assert abs(pr.link_busy - l_busy) < 1e-9
    assert abs(pr.cloud_busy - c_busy) < 1e-9
    for t, (i, arr, done, lat, ee) in zip(pr.tasks, recs):
        assert t.id == i and t.early_exit == ee
        assert abs(t.done - done) < 1e-9
        assert abs(t.latency - lat) < 1e-9


# --------------------------------------------- per-edge arrival regression
def test_per_edge_arrival_not_overwritten():
    """One end producer feeding two boundary edges: the first consumer must
    be gated on *its* transfer, not on the producer's last transfer (the
    seed recorded arrivals per producer and overwrote the earlier one)."""
    from repro.core.costs import LayerNode

    bw = 100e6
    g = ModelGraph("fanout", [
        LayerNode(0, "p", 1e6, 100_000),           # end producer
        LayerNode(1, "c1", 1e6, 1_000, (0,)),       # cloud, cheap transfer
        LayerNode(2, "c2", 1e6, 1_000, (0,)),       # cloud, heavy transfer
    ])
    dec = PartitionDecision(frozenset({0}), {(0, 1): 8, (0, 2): 32})
    st = evaluate_partition(g, dec, END, CLOUD, LinkProfile("l", bw))
    t_p = 1e6 / 1e9                                  # producer compute
    tx1 = 100_000 * 8 / bw                           # edge (0, 1)
    tx2 = 100_000 * 32 / bw                          # edge (0, 2)
    t_c = 1e6 / 8e9                                  # each cloud layer
    # per-edge semantics: c1 starts when ITS edge lands, c2 after both
    want_latency = max(t_p + tx1 + t_c, t_p + tx1 + tx2 + t_c)
    buggy_latency = t_p + tx1 + tx2 + 2 * t_c        # c1 gated on last tx
    assert abs(st.latency - want_latency) < 1e-12
    assert st.latency < buggy_latency - 1e-12


# ------------------------------------------------------- 3-hop properties
def _random_multihop_plans(rng, n, n_hops=2):
    plans = []
    for _ in range(n):
        comp = rng.uniform(1e-3, 4e-3, n_hops + 1)
        tx = rng.uniform(0.2e-3, 3e-3, n_hops)
        if rng.rand() < 0.15:
            plans.append(TaskPlan(comp[0], 0.0, 0.0, True))
            continue
        txo = [rng.uniform(0, comp[k]) if rng.rand() < 0.5 else None
               for k in range(n_hops)]
        rxo = [rng.uniform(0, tx[k]) if rng.rand() < 0.5 else None
               for k in range(n_hops)]
        plans.append(TaskPlan.multihop(comp, tx, txo, rxo))
    return plans


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_stream_busy_bounded_by_makespan(seed):
    rng = np.random.RandomState(seed)
    plans = _random_multihop_plans(rng, 50)
    pr = run_pipeline(plans, arrival_period=float(rng.uniform(1e-3, 4e-3)))
    assert pr.n_hops == 2
    for k in range(3):
        assert pr.compute_busy[k] <= pr.makespan + 1e-9
        assert 0.0 <= pr.bubble_fraction(("compute", k)) <= 1.0
    for k in range(2):
        assert pr.link_busy_hops[k] <= pr.makespan + 1e-9
        assert 0.0 <= pr.bubble_fraction(("link", k)) <= 1.0
    # causality: the first and last compute stages are serial within a task
    for t, p in zip(pr.tasks, plans):
        floor = p.t_end if p.early_exit \
            else max(p.compute[0], p.compute[-1])
        assert t.latency >= floor - 1e-12


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stream_latency_monotone_in_added_hop_time(seed):
    rng = np.random.RandomState(seed)
    plans = _random_multihop_plans(rng, 40)
    base = run_pipeline(plans, arrival_period=2e-3)
    for hop, field in ((0, "tx"), (1, "tx"), (1, "compute")):
        bumped = []
        for p in plans:
            if p.early_exit or not p.compute:
                bumped.append(p)
                continue
            comp, tx = list(p.compute), list(p.tx)
            if field == "tx":
                tx[hop] += 1e-3
            else:
                comp[hop] += 1e-3
            bumped.append(TaskPlan.multihop(comp, tx, p.tx_offsets,
                                            p.rx_offsets))
        pr = run_pipeline(bumped, arrival_period=2e-3)
        assert pr.mean_latency >= base.mean_latency - 1e-12, (hop, field)
        assert pr.makespan >= base.makespan - 1e-12, (hop, field)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_multihop_stage_times_properties(seed):
    g = _chain(seed, n=12)
    n = len(g)
    rng = np.random.RandomState(seed + 100)
    for _ in range(5):
        c1, c2 = sorted(rng.choice(range(1, n), size=2, replace=True))
        f1, f2 = frozenset(range(c1)), frozenset(range(c2))
        hop_bits = [{(c1 - 1, c1): 8} if c1 < n else {},
                    {(c2 - 1, c2): 8} if c2 < n else {}]
        dec = PartitionDecision.multihop([f1, f2], hop_bits)
        st = evaluate_multihop(g, dec, (END, EDGE, CLOUD), (LINK, BACKHAUL))
        assert st.n_hops == 2
        assert st.B_c >= 0 and st.B_t >= 0
        assert st.max_stage - 1e-12 <= st.latency <= st.stage_sum + 1e-9
        assert abs(sum(st.compute) -
                   sum(END.layer_time(nd.flops, nd.util) for nd in g.nodes
                       if nd.id < c1) -
                   sum(EDGE.layer_time(nd.flops, nd.util) for nd in g.nodes
                       if c1 <= nd.id < c2) -
                   sum(CLOUD.layer_time(nd.flops, nd.util) for nd in g.nodes
                       if nd.id >= c2)) < 1e-9


def test_empty_middle_segment_matches_two_hop():
    """A 3-hop deployment whose middle tier is empty and whose backhaul is
    effectively infinite must reproduce the 2-hop numbers (relay identity
    of the generalized core)."""
    g = _chain(11)
    n = len(g)
    cut = n // 2
    f = frozenset(range(cut))
    bits = {(cut - 1, cut): 8}
    st2 = evaluate_partition(g, PartitionDecision(f, bits), END, CLOUD, LINK)
    fast = LinkProfile("inf", 1e18)
    dec3 = PartitionDecision.multihop([f, f], [bits, dict(bits)])
    st3 = evaluate_multihop(g, dec3, (END, EDGE, CLOUD), (LINK, fast))
    assert abs(st3.latency - st2.latency) < 1e-6
    assert abs(st3.compute[0] - st2.T_e) < 1e-12
    assert st3.compute[1] == 0.0
    assert abs(st3.compute[-1] - st2.T_c) < 1e-12
    assert abs(st3.link[0] - st2.T_t) < 1e-12
